"""Self-tuning serving: recall-SLO autotuner + per-query escalation.

Closes the knob loop the ROADMAP names: ``SearchResult.stats`` already
reports what every query COST (``distance_evals``, ``beam_hops``); this
package decides what every query SHOULD cost.

* :mod:`repro.tune.autotune` — offline: sweep the
  :data:`~repro.api.index.KNOB_LADDER` on held-out queries, fit the
  Pareto :class:`OperatingCurve` (recall vs. distance_evals/QPS),
  persist it keyed by ``index.fingerprint()``. The serving engine maps
  ``target_recall`` through it to the cheapest operating point.
* :mod:`repro.tune.escalate` — online: the top-k margin-stability signal
  (:func:`topk_margin`) and :class:`EscalationPolicy`; the engine re-runs
  only unstable queries one ladder rung up.

See ``docs/autotune.md`` for the end-to-end story and
``benchmarks/table8_autotune.py`` for the gated before/after numbers.
"""
from ..api.index import KNOB_LADDER, SearchParams, next_rung, snap_knob
from .autotune import (
    OperatingCurve,
    OperatingPoint,
    candidate_params,
    curve_path,
    load_curve,
    pareto,
    save_curve,
    sweep,
)
from .escalate import EscalationPolicy, topk_margin, unstable_rows

__all__ = [
    "EscalationPolicy",
    "KNOB_LADDER",
    "OperatingCurve",
    "OperatingPoint",
    "SearchParams",
    "candidate_params",
    "curve_path",
    "load_curve",
    "next_rung",
    "pareto",
    "save_curve",
    "snap_knob",
    "sweep",
    "topk_margin",
    "unstable_rows",
]
