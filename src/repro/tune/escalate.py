"""Per-query adaptive escalation: the top-k margin-stability signal.

The offline autotuner (``repro.tune.autotune``) picks ONE operating point
per recall SLO, but query difficulty is heavy-tailed: most queries reach
the target well below the tuned knobs, a few need more. QPAD/MPAD
(PAPERS.md) show the quantile structure of neighbor-score margins is the
right per-query difficulty signal, and RAE's Eq. 15 norm-distortion band
bounds how much a reduced-space margin can lie about the exact-space one
— so a WIDE top-k margin in the space we searched certifies the result,
while a NARROW one flags a query whose true neighbors may sit just past
the beam/probe boundary.

The signal is computed from the scores a cheap pass already produced — no
extra distance evaluations. The first pass over-fetches ``k + delta``
candidates; for each query the *normalized tail margin*

    margin = (s[k-1] - s[k+delta-1]) / (s[0] - s[k+delta-1])

measures how decisively the k-th neighbor separates from the
(k+delta)-th, on the query's own score scale (scores are
higher-is-closer). ``margin`` lives in [0, 1]: near 0 means the boundary
is a coin flip (candidates past the cut are essentially tied with the
k-th — a deeper search could easily reorder them), near 1 means the top-k
is insulated from the tail. Rows whose margin falls below ``threshold``
— plus rows whose probe came up short of ``k + delta`` finite candidates
at all (when the corpus is big enough that it shouldn't) — are re-run one
:data:`~repro.api.index.KNOB_LADDER` rung up by the serving engine
(``SearchEngine``), which splits the coalesced batch: stable rows answer
immediately, unstable rows pay for a second pass.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from ..api.index import SearchParams


@dataclass(frozen=True)
class EscalationPolicy:
    """When and how the engine re-runs unstable queries.

    ``delta`` — how far past k the first pass over-fetches; the margin is
    measured between the k-th and (k+delta)-th scores. ``threshold`` —
    normalized-margin cut in [0, 1]: 0 never escalates, values > 1 always
    escalate (every finite margin is <= 1 — the test suites' forcing
    knob). ``params`` — explicit pass-2 operating point; ``None`` derives
    it as one ladder rung up from the engine's resolved pass-1 point
    (:meth:`SearchParams.escalated`). ``recall_slack`` — the recall
    deficit escalation is trusted to close: the curve's points were
    measured WITHOUT escalation, so the engine selects the cheapest
    point reaching ``target_recall - recall_slack`` (often one rung
    cheaper) and leans on the escalation pass to recover the gap —
    the bench gate (``scripts/check_bench.py`` autotune block) verifies
    the SLO still holds on held-out queries."""

    delta: int = 3
    threshold: float = 0.15
    params: Optional[SearchParams] = None
    recall_slack: float = 0.0

    def __post_init__(self):
        if self.delta < 1:
            raise ValueError(f"delta must be >= 1, got {self.delta}")
        if self.threshold < 0.0:
            raise ValueError(
                f"threshold must be >= 0, got {self.threshold}")
        if self.recall_slack < 0.0:
            raise ValueError(
                f"recall_slack must be >= 0, got {self.recall_slack}")

    def key(self) -> tuple:
        """Hashable identity for cache keys / operating-point tokens."""
        return (self.delta, float(self.threshold),
                None if self.params is None else self.params.key(),
                float(self.recall_slack))


def topk_margin(scores: np.ndarray, k: int, delta: int) -> np.ndarray:
    """Normalized tail margin per row, from a [Q, >= k+delta] score matrix
    (higher = closer, descending per row — every tier's output contract).

    Rows without ``k + delta`` finite candidates get margin NaN: the
    probe/beam came up short, so the margin is undefined there (the
    caller decides whether short rows escalate — see
    :func:`unstable_rows`). A degenerate full-tie row (s[0] == s[k+delta-1])
    gets margin 0.0: indistinguishable candidates are the definition of
    an unstable boundary."""
    kk = k + delta
    if scores.shape[1] < kk:
        raise ValueError(f"need k+delta={kk} scores per row, "
                         f"got {scores.shape[1]}")
    s = np.asarray(scores, np.float64)
    top, kth, tail = s[:, 0], s[:, k - 1], s[:, kk - 1]
    finite = np.isfinite(top) & np.isfinite(tail)
    span = top - tail
    margin = np.full(s.shape[0], np.nan)
    ok = finite & (span > 0)
    margin[ok] = (kth[ok] - tail[ok]) / span[ok]
    margin[finite & (span <= 0)] = 0.0
    return margin


def unstable_rows(scores: np.ndarray, k: int, delta: int,
                  threshold: float,
                  ntotal: Optional[int] = None) -> np.ndarray:
    """Boolean mask of rows the engine should re-run at the next rung.

    A row escalates when its normalized margin is below ``threshold``, or
    when the margin is undefined because the cheap pass produced fewer
    than ``k + delta`` finite candidates — *if* the corpus actually holds
    that many rows (``ntotal``): an IVF probe that came up short is
    exactly the hard case a wider probe fixes, whereas a tiny corpus
    simply has nothing more to find and re-searching it is pure waste."""
    margin = topk_margin(scores, k, delta)
    short = np.isnan(margin)
    out = np.zeros(margin.shape[0], bool)
    fin = ~short
    out[fin] = margin[fin] < threshold
    if ntotal is None or ntotal >= k + delta:
        out |= short
    return out
