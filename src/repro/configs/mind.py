"""mind [arXiv:1904.08030; unverified]

embed_dim=64 n_interests=4 capsule_iters=3 interaction=multi-interest
(dynamic-routing capsules over the user behavior sequence).
"""
from .base import EmbeddingTableSpec, RecsysConfig

CONFIG = RecsysConfig(
    name="mind",
    kind="mind",
    embed_dim=64,
    n_interests=4,
    capsule_iters=3,
    hist_len=50,
    mlp_dims=(256, 64),
    tables=(
        EmbeddingTableSpec("item", vocab=2_000_000, dim=64),
        EmbeddingTableSpec("category", vocab=5_000, dim=64),
    ),
)
FAMILY = "recsys"
