"""Arch registry: ``--arch <id>`` -> (config, family, shape set)."""
from __future__ import annotations

import importlib
from typing import Any

from .base import ShapeCell
from .shapes import shapes_for_family

_ARCH_MODULES = {
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "phi3-medium-14b": "phi3_medium_14b",
    "qwen2-7b": "qwen2_7b",
    "llama3.2-1b": "llama3_2_1b",
    "graphsage-reddit": "graphsage_reddit",
    "bst": "bst",
    "two-tower-retrieval": "two_tower_retrieval",
    "autoint": "autoint",
    "mind": "mind",
    "rae_paper": "rae_paper",
}

ARCH_IDS = tuple(k for k in _ARCH_MODULES if k != "rae_paper")


def get_arch(arch_id: str) -> tuple[Any, str]:
    """Return (config, family) for an arch id."""
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    return mod.CONFIG, mod.FAMILY


def get_shapes(arch_id: str) -> tuple[ShapeCell, ...]:
    _, family = get_arch(arch_id)
    if family == "rae":
        return ()
    return shapes_for_family(family)


def all_cells() -> list[tuple[str, ShapeCell]]:
    """All 40 (arch, shape) cells."""
    out = []
    for arch_id in ARCH_IDS:
        for cell in get_shapes(arch_id):
            out.append((arch_id, cell))
    return out
