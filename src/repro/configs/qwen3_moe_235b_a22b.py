"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-30B-A3B family scaling; hf]

94L d_model=4096 64H (GQA kv=4) per-expert d_ff=1536 vocab=151936, MoE 128e top-8.
"""
from .base import TransformerConfig

CONFIG = TransformerConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    d_head=128,
    d_ff=1536,
    vocab_size=151936,
    n_experts=128,
    moe_top_k=8,
    rope_theta=1_000_000.0,
    qkv_bias=False,
    qk_norm=True,
)
FAMILY = "lm"
