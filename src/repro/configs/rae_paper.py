"""The paper's own RAE configuration (Section 4.1).

3000 steps, batch 128, AdamW with weight decay = lambda, cosine 1e-3 -> 1e-5.
in/out dims are dataset-dependent; this default matches the IMDb(768d)->384
setting of Table 1.
"""
from .base import RAEConfig

CONFIG = RAEConfig(
    name="rae_paper",
    in_dim=768,
    out_dim=384,
    weight_decay=1e-2,
    steps=3000,
    batch_size=128,
    lr_max=1e-3,
    lr_min=1e-5,
)
FAMILY = "rae"
