from .base import (
    EmbeddingTableSpec,
    GNNConfig,
    RAEConfig,
    RecsysConfig,
    ShapeCell,
    TransformerConfig,
)
from .registry import ARCH_IDS, all_cells, get_arch, get_shapes

__all__ = [
    "ARCH_IDS",
    "EmbeddingTableSpec",
    "GNNConfig",
    "RAEConfig",
    "RecsysConfig",
    "ShapeCell",
    "TransformerConfig",
    "all_cells",
    "get_arch",
    "get_shapes",
]
