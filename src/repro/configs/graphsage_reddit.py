"""graphsage-reddit [arXiv:1706.02216; paper]

n_layers=2 d_hidden=128 aggregator=mean sample_sizes=25-10.
"""
from .base import GNNConfig

CONFIG = GNNConfig(
    name="graphsage-reddit",
    n_layers=2,
    d_hidden=128,
    aggregator="mean",
    sample_sizes=(25, 10),
    n_classes=41,
)
FAMILY = "gnn"
