"""phi3-medium-14b [arXiv:2404.14219; unverified]

40L d_model=5120 40H (GQA kv=10) d_ff=17920 vocab=100352 — RoPE SwiGLU GQA.
40 heads is not divisible by TP=16 -> attention_scheme resolves to
context-parallel (DESIGN.md §5); hillclimb pads heads to 48 for head-TP.
"""
from .base import TransformerConfig

CONFIG = TransformerConfig(
    name="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    d_head=128,
    d_ff=17920,
    vocab_size=100352,
    rope_theta=10_000.0,
    qkv_bias=False,
)
FAMILY = "lm"
