"""Config dataclasses for every architecture family and input-shape cell.

Configs are immutable dataclasses; the registry (``repro.configs.registry``)
maps ``--arch`` ids to (config, shape-set) pairs. Shape cells carry everything
needed to build ``input_specs()`` stand-ins for the dry-run.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Optional


@dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) cell: what gets lowered for an architecture."""

    name: str
    kind: str  # train | prefill | decode | full_graph | minibatch | serve | retrieval
    # LM fields
    seq_len: int = 0
    global_batch: int = 0
    # GNN fields
    n_nodes: int = 0
    n_edges: int = 0
    d_feat: int = 0
    batch_nodes: int = 0
    fanout: tuple[int, ...] = ()
    graphs_per_batch: int = 0
    # RecSys fields
    n_candidates: int = 0
    extras: dict[str, Any] = field(default_factory=dict)

    def replace(self, **kw) -> "ShapeCell":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class TransformerConfig:
    name: str
    family: str  # "dense" | "moe"
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab_size: int
    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01
    router_z_weight: float = 1e-3
    # flavor
    rope_theta: float = 1_000_000.0
    qkv_bias: bool = False
    qk_norm: bool = False
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    kv_chunk: int = 256  # online-softmax KV block size
    # numerics / memory
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    moment_dtype: str = "bfloat16"  # Adam m/v dtype (fp32 master retained)
    remat: bool = True
    scan_layers: bool = True
    # attention scheme: "auto" picks head-TP when n_heads % tp == 0 else context-parallel
    attention_scheme: str = "auto"
    # beyond-paper perf knobs (hillclimbed; see EXPERIMENTS.md §Perf)
    pad_heads_to_tp: bool = False  # pad n_heads up to a multiple of TP for head-TP
    xent_chunk: int = 0  # 0 = unchunked cross-entropy; >0 = token-chunked logsumexp
    grad_accum: int = 1  # microbatches per step (activation memory / accum trade)

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.d_head

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.d_head

    def n_params(self) -> int:
        """Total parameter count (exact, incl. embeddings)."""
        d, L = self.d_model, self.n_layers
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.qkv_bias:
            attn += self.q_dim + 2 * self.kv_dim
        if self.family == "moe":
            mlp = self.n_experts * 3 * d * self.d_ff + d * self.n_experts  # + router
        else:
            mlp = 3 * d * self.d_ff
        norms = 2 * d
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return L * (attn + mlp + norms) + embed + d  # + final norm

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: only routed experts)."""
        if self.family != "moe":
            return self.n_params()
        d, L = self.d_model, self.n_layers
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        mlp = self.moe_top_k * 3 * d * self.d_ff + d * self.n_experts
        embed = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return L * (attn + mlp + 2 * d) + embed + d


@dataclass(frozen=True)
class GNNConfig:
    name: str
    n_layers: int
    d_hidden: int
    aggregator: str  # "mean" | "max" | "sum"
    sample_sizes: tuple[int, ...]
    n_classes: int = 41  # reddit has 41 classes
    param_dtype: str = "float32"
    compute_dtype: str = "float32"


@dataclass(frozen=True)
class EmbeddingTableSpec:
    name: str
    vocab: int
    dim: int
    # "bag" tables take multi-hot index lists and segment-reduce them
    bag_size: int = 0  # 0 => single-id lookup


@dataclass(frozen=True)
class RecsysConfig:
    name: str
    kind: str  # "bst" | "two_tower" | "autoint" | "mind"
    embed_dim: int
    tables: tuple[EmbeddingTableSpec, ...]
    mlp_dims: tuple[int, ...] = ()
    # bst
    seq_len: int = 0
    n_blocks: int = 0
    n_heads: int = 0
    # autoint
    n_attn_layers: int = 0
    d_attn: int = 0
    n_fields: int = 0
    # mind
    n_interests: int = 0
    capsule_iters: int = 0
    hist_len: int = 0
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    def n_params(self) -> int:
        n = sum(t.vocab * t.dim for t in self.tables)
        return n  # MLP params counted by model schema; tables dominate


@dataclass(frozen=True)
class RAEConfig:
    """The paper's own technique (Section 3.2) as a first-class config."""

    name: str = "rae_paper"
    in_dim: int = 768
    out_dim: int = 384
    # lambda: regularization coefficient; realised as AdamW decoupled weight
    # decay (paper's experimental setup) or as an explicit Frobenius term in
    # the loss (paper's Eq. 7) when explicit_frobenius=True.
    weight_decay: float = 1e-2
    explicit_frobenius: bool = False
    use_bias: bool = False  # paper footnote 2: biases cancel in distances
    steps: int = 3000
    batch_size: int = 128
    lr_max: float = 1e-3
    lr_min: float = 1e-5
    seed: int = 0
    param_dtype: str = "float32"

    def replace(self, **kw) -> "RAEConfig":
        return dataclasses.replace(self, **kw)


ArchConfig = Any  # TransformerConfig | GNNConfig | RecsysConfig
