"""Reduced (smoke-scale) configs: same family/topology, tiny dims — used by
per-arch smoke tests and `launch/train.py --scale smoke` on CPU."""
from __future__ import annotations

import dataclasses

from .base import (EmbeddingTableSpec, GNNConfig, RecsysConfig, ShapeCell,
                   TransformerConfig)


def reduce_config(cfg, family: str):
    if family == "lm":
        assert isinstance(cfg, TransformerConfig)
        moe = cfg.family == "moe"
        return dataclasses.replace(
            cfg,
            n_layers=2,
            d_model=64,
            n_heads=max(4, min(cfg.n_heads, 4)),
            n_kv_heads=2 if cfg.n_kv_heads < cfg.n_heads else 4,
            d_head=16,
            d_ff=64 if moe else 128,
            vocab_size=251,
            n_experts=8 if moe else 0,
            moe_top_k=min(cfg.moe_top_k, 2) if moe else 0,
            kv_chunk=16,
            xent_chunk=8,
        )
    if family == "gnn":
        assert isinstance(cfg, GNNConfig)
        return dataclasses.replace(cfg, d_hidden=32)
    if family == "recsys":
        assert isinstance(cfg, RecsysConfig)
        tables = tuple(
            dataclasses.replace(t, vocab=min(t.vocab, 1000)) for t in cfg.tables)
        return dataclasses.replace(
            cfg, tables=tables,
            mlp_dims=tuple(min(d, 64) for d in cfg.mlp_dims))
    raise ValueError(family)


def reduce_cell(cell: ShapeCell, family: str) -> ShapeCell:
    if family == "lm":
        return cell.replace(seq_len=min(cell.seq_len, 64),
                            global_batch=min(cell.global_batch, 4))
    if family == "gnn":
        kw = {}
        if cell.n_nodes:
            kw["n_nodes"] = min(cell.n_nodes, 256)
        if cell.n_edges:
            kw["n_edges"] = min(cell.n_edges, 1024)
        if cell.d_feat:
            kw["d_feat"] = min(cell.d_feat, 32)
        if cell.batch_nodes:
            kw["batch_nodes"] = min(cell.batch_nodes, 32)
        if cell.fanout:
            kw["fanout"] = tuple(min(f, 4) for f in cell.fanout)
        if cell.graphs_per_batch:
            kw["graphs_per_batch"] = min(cell.graphs_per_batch, 8)
        return cell.replace(**kw)
    if family == "recsys":
        kw = {"global_batch": min(cell.global_batch, 32) or 1}
        if cell.n_candidates:
            kw["n_candidates"] = min(cell.n_candidates, 512)
        return cell.replace(**kw)
    raise ValueError(family)
