"""The assigned input-shape sets, one per architecture family (40 cells total)."""
from __future__ import annotations

from .base import ShapeCell

# --- LM-family transformers: seq_len x global_batch -------------------------
LM_SHAPES = (
    ShapeCell(name="train_4k", kind="train", seq_len=4096, global_batch=256),
    ShapeCell(name="prefill_32k", kind="prefill", seq_len=32768, global_batch=32),
    ShapeCell(name="decode_32k", kind="decode", seq_len=32768, global_batch=128),
    # long_500k is *decode* (one token vs a 524288-token KV cache): O(S)/step
    # even for full attention -> runnable for all five LM archs (DESIGN.md §9).
    ShapeCell(name="long_500k", kind="decode", seq_len=524288, global_batch=1),
)

# --- GNN (graphsage-reddit) --------------------------------------------------
GNN_SHAPES = (
    # cora-like full batch
    ShapeCell(name="full_graph_sm", kind="full_graph", n_nodes=2708, n_edges=10556,
              d_feat=1433, extras={"n_classes": 7}),
    # reddit sampled training
    ShapeCell(name="minibatch_lg", kind="minibatch", n_nodes=232965, n_edges=114_615_892,
              d_feat=602, batch_nodes=1024, fanout=(15, 10), extras={"n_classes": 41}),
    # ogbn-products full batch
    ShapeCell(name="ogb_products", kind="full_graph", n_nodes=2_449_029,
              n_edges=61_859_140, d_feat=100, extras={"n_classes": 47}),
    # batched small graphs
    ShapeCell(name="molecule", kind="batched_graphs", n_nodes=30, n_edges=64,
              d_feat=64, graphs_per_batch=128, extras={"n_classes": 2}),
)

# --- RecSys ------------------------------------------------------------------
RECSYS_SHAPES = (
    ShapeCell(name="train_batch", kind="train", global_batch=65536),
    ShapeCell(name="serve_p99", kind="serve", global_batch=512),
    ShapeCell(name="serve_bulk", kind="serve", global_batch=262144),
    ShapeCell(name="retrieval_cand", kind="retrieval", global_batch=1,
              n_candidates=1_000_000),
)


def shapes_for_family(family: str) -> tuple[ShapeCell, ...]:
    return {"lm": LM_SHAPES, "gnn": GNN_SHAPES, "recsys": RECSYS_SHAPES}[family]
