"""qwen2-7b [arXiv:2407.10671; hf]

28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064 — GQA, QKV bias.
28 heads not divisible by TP=16 -> context-parallel attention by default.
"""
from .base import TransformerConfig

CONFIG = TransformerConfig(
    name="qwen2-7b",
    family="dense",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_head=128,
    d_ff=18944,
    vocab_size=152064,
    rope_theta=1_000_000.0,
    qkv_bias=True,
)
FAMILY = "lm"
