"""autoint [arXiv:1810.11921; paper]

n_sparse=39 embed_dim=16 n_attn_layers=3 n_heads=2 d_attn=32,
interaction=multi-head self-attention over field embeddings (Criteo-style).
Per-field hashed vocab 200k (39 fields).
"""
from .base import EmbeddingTableSpec, RecsysConfig

CONFIG = RecsysConfig(
    name="autoint",
    kind="autoint",
    embed_dim=16,
    n_fields=39,
    n_attn_layers=3,
    n_heads=2,
    d_attn=32,
    mlp_dims=(),
    tables=tuple(
        EmbeddingTableSpec(f"field_{i}", vocab=200_000, dim=16) for i in range(39)
    ),
)
FAMILY = "recsys"
