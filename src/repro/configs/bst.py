"""bst — Behavior Sequence Transformer (Alibaba) [arXiv:1905.06874; paper]

embed_dim=32 seq_len=20 n_blocks=1 n_heads=8 mlp=1024-512-256,
interaction=transformer over the user's behavior sequence + target item.

Table sizes follow the paper's Taobao setting scaled to public-magnitude
vocabularies (items ~4M, categories 10k, users hashed 1M).
"""
from .base import EmbeddingTableSpec, RecsysConfig

CONFIG = RecsysConfig(
    name="bst",
    kind="bst",
    embed_dim=32,
    seq_len=20,
    n_blocks=1,
    n_heads=8,
    mlp_dims=(1024, 512, 256),
    tables=(
        EmbeddingTableSpec("item", vocab=4_000_000, dim=32),
        EmbeddingTableSpec("category", vocab=10_000, dim=32),
        EmbeddingTableSpec("user", vocab=1_000_000, dim=32),
    ),
)
FAMILY = "recsys"
