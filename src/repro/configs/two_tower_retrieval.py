"""two-tower-retrieval [RecSys'19 (YouTube); unverified]

embed_dim=256 tower_mlp=1024-512-256 interaction=dot, sampled-softmax retrieval.
Item corpus 10M ids; user side: id + multi-hot history bag (EmbeddingBag).
"""
from .base import EmbeddingTableSpec, RecsysConfig

CONFIG = RecsysConfig(
    name="two-tower-retrieval",
    kind="two_tower",
    embed_dim=256,
    mlp_dims=(1024, 512, 256),
    hist_len=50,
    tables=(
        EmbeddingTableSpec("user", vocab=5_000_000, dim=256),
        EmbeddingTableSpec("item", vocab=10_000_000, dim=256),
        EmbeddingTableSpec("hist_item", vocab=10_000_000, dim=256, bag_size=50),
    ),
)
FAMILY = "recsys"
