import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this lowers the real step function (train_step / prefill /
decode_step / serve / retrieval+top-k) with production shardings on the
16x16 single-pod mesh and the 2x16x16 multi-pod mesh, compiles it, and
records:
  * memory_analysis()  — per-device argument/output/temp bytes (fits check),
  * cost_analysis()    — per-device HLO FLOPs/bytes (scan bodies counted
                         once; see benchmarks/roofline.py for the adjusted
                         analytic terms),
  * loop-adjusted collective traffic from the compiled HLO
    (launch/hlo_analysis.py),
  * sharding fallbacks (logical axes that degraded to replication).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out results/dryrun.json
"""
import argparse
import json
import time
import traceback
from typing import Optional

import jax

from ..configs import ARCH_IDS, get_arch, get_shapes
from ..distributed.partitioning import default_rules
from ..models.common import MeshCtx
from ..models.registry import build_cell
from . import hlo_analysis
from .mesh import make_production_mesh


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             verbose: bool = True) -> dict:
    t0 = time.perf_counter()
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = MeshCtx(mesh=mesh, rules=default_rules(multi_pod=multi_pod))
    prog = build_cell(arch_id, shape_name, ctx)

    lowered = prog.lower(mesh)
    t_lower = time.perf_counter() - t0
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower

    ma = compiled.memory_analysis()
    ca = compiled.cost_analysis() or {}
    text = compiled.as_text()
    coll = hlo_analysis.collective_bytes(text)
    counts = hlo_analysis.count_collectives(text)

    rec = {
        "arch": arch_id,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "ok": True,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_est_bytes": (ma.argument_size_in_bytes
                               + ma.output_size_in_bytes
                               + ma.temp_size_in_bytes
                               - ma.alias_size_in_bytes),
        },
        "cost": {
            "hlo_flops_per_device": ca.get("flops", 0.0),
            "hlo_bytes_per_device": ca.get("bytes accessed", 0.0),
        },
        "collectives_bytes": coll,
        "collectives_count": counts,
        "meta": prog.meta,
    }
    if verbose:
        gb = rec["memory"]["peak_est_bytes"] / 2**30
        print(f"  [OK] {arch_id} x {shape_name} x {rec['mesh']}: "
              f"peak ~{gb:.2f} GiB/dev, "
              f"flops/dev {rec['cost']['hlo_flops_per_device']:.3g}, "
              f"coll {coll.get('total', 0)/2**30:.3f} GiB/dev "
              f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)")
    return rec


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun.json")
    ap.add_argument("--append", action="store_true",
                    help="merge into an existing results file")
    args = ap.parse_args(argv)

    archs = list(ARCH_IDS) if args.arch == "all" else args.arch.split(",")
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    if args.append and os.path.exists(args.out):
        results = json.load(open(args.out))
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results if r.get("ok")}

    n_fail = 0
    for arch_id in archs:
        shapes = [c.name for c in get_shapes(arch_id)]
        if args.shape != "all":
            shapes = [s for s in args.shape.split(",") if s in shapes]
        for shape_name in shapes:
            for mp in meshes:
                key = (arch_id, shape_name, "2x16x16" if mp else "16x16")
                if key in done:
                    continue
                try:
                    results.append(run_cell(arch_id, shape_name, mp))
                except Exception as e:  # noqa: BLE001 — record and continue
                    n_fail += 1
                    print(f"  [FAIL] {key}: {type(e).__name__}: {e}")
                    traceback.print_exc(limit=3)
                    results.append({"arch": arch_id, "shape": shape_name,
                                    "mesh": key[2], "ok": False,
                                    "error": f"{type(e).__name__}: {e}"})
                os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
                json.dump(results, open(args.out, "w"), indent=1)
    ok = sum(1 for r in results if r.get("ok"))
    print(f"dry-run: {ok} ok / {len(results)} cells -> {args.out}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
