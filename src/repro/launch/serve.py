"""Vector-search serving launcher on the unified ``repro.api`` surface.

The index stack is a FAISS-style spec string (``--index-spec``), built by
``api.index_factory`` — any registered reducer composed with any base
index::

    RAE64,Flat,Rerank4         # the paper stack: RAE -> reduced scan -> rerank
    RAE64,IVF256,Rerank4       # + coarse quantization in the reduced space
    RAE64,HNSW32,Rerank4       # + graph beam search: sublinear per-query work
    RAE64,IVF256,PQ8x8,Rerank4 # + PQ list payloads (8 bytes/vector, ADC)
    RAE32,SQ8                  # reduce, then int8 scalar codes
    PCA64,Flat,Rerank4         # baseline reducer, same serving path
    Flat                       # exact full-space scan (the recall reference)

Every batch reports ``distance_evals`` — the mean number of corpus vectors
whose distance each query evaluated (scan = N; HNSW = beam-visited count)
— so the sublinearity of a graph stack is visible next to recall/latency.
``--ef-search`` tunes the HNSW beam width at serve time.

Built indexes persist (``--save-index DIR``) and reload without retraining
(``--load-index DIR``) — cold starts no longer pay the RAE training bill.

The built index is wrapped in :class:`repro.serve.SearchEngine` (warmed up
at every padded batch size). Two modes:

* default: a closed-loop benchmark through the engine's batch path,
  reporting recall vs the exact scan + the engine stats surface;
* ``--serve``: stay up as an HTTP service (``POST /search``,
  ``GET /stats``, ``GET /healthz``) where concurrent single-query clients
  are coalesced by the micro-batching scheduler
  (``--max-batch`` / ``--max-wait-ms`` / ``--cache-size``).

Smoke-scale by default so it runs anywhere:
  PYTHONPATH=src python -m repro.launch.serve --n 20000 --dim 256 --m 64
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Optional

import numpy as np

from .. import api
from ..data import synthetic
from ..serve import SearchEngine, make_server


def build_or_load_index(args) -> tuple[api.VectorIndex, np.ndarray]:
    """Returns (ready index, corpus). The corpus is synthesized either way:
    a loaded index serves it from its own persisted state, but the recall
    reference scan still needs the raw vectors."""
    corpus = synthetic.embedding_corpus(args.n, args.dim, n_clusters=16,
                                        intrinsic=args.dim // 4,
                                        seed=args.seed)
    if args.load_index:
        print(f"[2/5] loading index from {args.load_index}")
        index = api.load_index(args.load_index)
        if args.ef_search is not None:
            # ef_search is a pure query-time knob: retune the beam on a
            # loaded graph instead of silently serving the saved width
            hnsw = index.base if isinstance(index, api.TwoStageIndex) \
                else index
            if isinstance(hnsw, api.HNSWIndex):
                hnsw.ef_search = args.ef_search
                print(f"      ef_search -> {args.ef_search}")
        if index.ntotal != args.n:
            raise SystemExit(
                f"loaded index holds {index.ntotal} vectors but "
                f"--n={args.n}: the recall reference would compare ids "
                f"across different corpora. Re-serve with --n "
                f"{index.ntotal} (and the --dim/--seed the index was "
                f"built with).")
        if index.dim != args.dim:
            raise SystemExit(
                f"loaded index takes {index.dim}-d queries but "
                f"--dim={args.dim}: re-serve with --dim {index.dim}.")
        return index, corpus

    spec = args.index_spec or f"RAE{args.m},Flat,Rerank{args.rerank_factor}"
    parsed = api.parse_index_spec(spec)
    reducer_kw = {}
    if parsed.reducer == "rae":
        reducer_kw = dict(steps=args.steps, weight_decay=args.weight_decay,
                          seed=args.seed)
    index_kw = {}
    if parsed.base == "hnsw":
        index_kw = dict(ef_construction=args.ef_construction or 100,
                        ef_search=args.ef_search or 64, seed=args.seed)
    print(f"[2/5] building {spec!r}"
          + (f" (rae: {args.steps} steps, lambda={args.weight_decay})"
             if reducer_kw else "")
          + (f" (hnsw: efC={index_kw['ef_construction']}, "
             f"efS={index_kw['ef_search']})" if index_kw else ""))
    index = api.index_factory(spec, reducer_kw=reducer_kw, index_kw=index_kw)
    t0 = time.perf_counter()
    index.build(corpus)
    print(f"      built in {time.perf_counter() - t0:.2f}s "
          f"(ntotal={index.ntotal}, "
          f"{index.bytes_per_vector:.0f} bytes/vector stage-1)")
    return index, corpus


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--m", type=int, default=64,
                    help="reducer target dim for the default spec")
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--rerank-factor", type=int, default=4)
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--weight-decay", type=float, default=1e-2)
    ap.add_argument("--ef-construction", type=int, default=None,
                    help="HNSW insert-time beam width (default 100; "
                         "HNSW specs only)")
    ap.add_argument("--ef-search", type=int, default=None,
                    help="HNSW query-time beam width, the recall/latency "
                         "knob (default 64); also retunes a --load-index'd "
                         "graph")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--index-spec", default=None,
                    help='factory spec, e.g. "RAE64,IVF256,PQ8x8,Rerank4" '
                         'or "RAE32,SQ8" '
                         "(default: RAE<m>,Flat,Rerank<rerank-factor>)")
    ap.add_argument("--save-index", default=None, metavar="DIR",
                    help="persist the built index (reducer + base + corpus)")
    ap.add_argument("--load-index", default=None, metavar="DIR",
                    help="serve a previously saved index (skips training)")
    ap.add_argument("--serve", action="store_true",
                    help="stay up as an HTTP service instead of running "
                         "the one-shot benchmark loop")
    ap.add_argument("--port", type=int, default=8000,
                    help="HTTP port for --serve (0 picks a free one)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--max-batch", type=int, default=32,
                    help="scheduler: coalesce at most this many concurrent "
                         "single-query requests per index.search call")
    ap.add_argument("--max-wait-ms", type=float, default=2.0,
                    help="scheduler: max wait after the first queued "
                         "request before flushing a partial batch")
    ap.add_argument("--cache-size", type=int, default=1024,
                    help="LRU result-cache entries (0 disables)")
    args = ap.parse_args(argv)

    print(f"[1/5] corpus: {args.n} x {args.dim}")
    index, corpus = build_or_load_index(args)

    if args.save_index:
        index.save(args.save_index)
        print(f"      saved -> {args.save_index}")

    engine = SearchEngine(index, max_batch=args.max_batch,
                          max_wait_ms=args.max_wait_ms,
                          cache_size=args.cache_size)

    if args.serve:
        print(f"[3/5] engine warm-up: buckets {engine.buckets}, k={args.k}")
        engine.start().warmup(ks=(args.k,))  # dim from the index itself
        server = make_server(engine, port=args.port, host=args.host)
        host, port = server.server_address[:2]
        print(f"[4/5] serving http://{host}:{port} "
              f"(POST /search, GET /stats, GET /healthz) — ^C to stop")
        try:
            server.serve_forever()
        except KeyboardInterrupt:
            pass
        finally:
            server.shutdown()
            print("[5/5] final stats:")
            print(json.dumps(engine.stats(), indent=1))
            engine.stop()
        return 0

    print("[3/5] exact reference index (recall baseline)")
    exact = api.FlatIndex().build(corpus)

    print(f"[4/5] serving {args.batches} batches x {args.queries} queries "
          "through the engine")
    rng = np.random.default_rng(args.seed + 1)
    lat, recalls = [], []
    for _ in range(args.batches):
        q = corpus[rng.integers(0, args.n, args.queries)] + \
            0.01 * rng.standard_normal(
                (args.queries, args.dim)).astype(np.float32)
        res = engine.search(q, args.k)
        lat.append(res.latency_s)
        ref = exact.search(q, args.k)
        inter = (ref.indices[:, :, None] ==
                 res.indices[:, None, :]).any(-1).mean()
        recalls.append(float(inter))
    lat_ms = np.array(lat[1:] or lat) * 1e3  # drop compile batch
    stats = engine.stats()
    evals_str = ""
    if "distance_evals" in stats:
        ev = stats["distance_evals"]
        evals_str = (f" | distance evals/query {ev:.0f} "
                     f"({ev / args.n:.1%} of corpus)")
    print(f"[5/5] recall@{args.k}: {np.mean(recalls):.4f} | "
          f"latency p50 {np.percentile(lat_ms, 50):.2f} ms "
          f"p99 {np.percentile(lat_ms, 99):.2f} ms" + evals_str)
    print(f"      engine: {stats['requests']} queries in "
          f"{stats['batches']} batches, qps={stats['qps']:.1f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
