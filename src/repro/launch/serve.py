"""Vector-search serving launcher: the paper's technique as a service.

Pipeline (matches examples/rae_retrieval.py, batch-request form):
  1. load/synthesize an embedding corpus, shard it over the mesh,
  2. train (or restore) an RAE encoder,
  3. encode the corpus into R^m (rae_encode kernel path on TPU),
  4. serve batched k-NN queries: two-stage (reduced scan -> full rerank),
  5. report recall@k vs the exact full-space scan and latency percentiles.

Smoke-scale by default so it runs anywhere:
  PYTHONPATH=src python -m repro.launch.serve --n 20000 --dim 256 --m 64
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import RAEConfig
from ..core import trainer
from ..data import synthetic
from ..models.common import MeshCtx, NULL_CTX
from ..search import two_stage_search, search as exact_search, encode_corpus
from .mesh import make_host_mesh


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=20000)
    ap.add_argument("--dim", type=int, default=256)
    ap.add_argument("--m", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--batches", type=int, default=8)
    ap.add_argument("--rerank-factor", type=int, default=4)
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--weight-decay", type=float, default=1e-2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    ctx = NULL_CTX  # host-scale; production uses make_production_mesh

    print(f"[1/5] corpus: {args.n} x {args.dim}")
    corpus = synthetic.embedding_corpus(args.n, args.dim, n_clusters=16,
                                        intrinsic=args.dim // 4,
                                        seed=args.seed)
    db = jnp.asarray(corpus)

    print(f"[2/5] training RAE {args.dim} -> {args.m} "
          f"(lambda={args.weight_decay}, {args.steps} steps)")
    cfg = RAEConfig(in_dim=args.dim, out_dim=args.m, steps=args.steps,
                    weight_decay=args.weight_decay, seed=args.seed)
    res = trainer.train(cfg, corpus, log_every=200)
    print(f"      train {res.wall_time_s:.2f}s, "
          f"final loss {res.history[-1]['loss']:.4f}")

    print("[3/5] encoding corpus")
    db_red = encode_corpus(res.params, db, ctx)

    print(f"[4/5] serving {args.batches} batches x {args.queries} queries")
    rng = np.random.default_rng(args.seed + 1)
    lat, recalls = [], []
    ts = jax.jit(lambda q: two_stage_search(
        q, db, db_red, res.params, args.k, ctx,
        rerank_factor=args.rerank_factor))
    ex = jax.jit(lambda q: exact_search(q, db, args.k, ctx))
    for b in range(args.batches):
        q = db[rng.integers(0, args.n, args.queries)] + \
            0.01 * rng.standard_normal((args.queries, args.dim)).astype(np.float32)
        t0 = time.perf_counter()
        _, idx = ts(q)
        jax.block_until_ready(idx)
        lat.append(time.perf_counter() - t0)
        _, exact_idx = ex(q)
        inter = (jnp.asarray(exact_idx)[:, :, None] ==
                 jnp.asarray(idx)[:, None, :]).any(-1).mean()
        recalls.append(float(inter))
    lat_ms = np.array(lat[1:]) * 1e3  # drop compile batch
    print(f"[5/5] recall@{args.k}: {np.mean(recalls):.4f} | "
          f"latency p50 {np.percentile(lat_ms, 50):.2f} ms "
          f"p99 {np.percentile(lat_ms, 99):.2f} ms "
          f"(compression {args.dim}/{args.m} = {args.dim/args.m:.1f}x)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
