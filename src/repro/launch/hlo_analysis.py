"""HLO post-compile analysis: collective-traffic accounting with while-loop
trip-count multipliers.

``compiled.cost_analysis()`` counts a ``lax.scan`` body once (verified in
EXPERIMENTS.md §Dry-run), and collectives inside the layer scan appear once
in the HLO text. This module parses the compiled module, recovers each
while loop's trip count from its condition computation's comparison
constant, and walks the call graph multiplying collective bytes by the
enclosing loops' trip counts — giving honest per-step collective traffic.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"([a-z]+[0-9]*)\[([0-9,]*)\]")
_INSTR_RE = re.compile(r"^\s*(ROOT\s+)?(%[\w\.\-]+)\s*=\s*(.*)$")
# computation headers: "%name (p: type, ...) -> type {" — params may contain
# nested parens (tuple types), so match greedily up to the "->"
_COMP_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_CALLED_RE = re.compile(
    r"(?:body|condition|to_apply|calls|branch_computations)=\{?%?([\w\.\-,% ]+)\}?")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")


def _shape_bytes(type_str: str) -> int:
    """Total bytes of possibly-tuple HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclass
class Computation:
    name: str
    is_entry: bool = False
    # (op_kind, bytes) for each collective instruction
    collectives: list = field(default_factory=list)
    # (called_computation_name, kind) pairs; kind 'while_body' carries a trip count
    whiles: list = field(default_factory=list)  # (body, cond) names
    calls: list = field(default_factory=list)   # plain calls / fusions
    # constants found (used when this computation is a while condition)
    max_constant: int = 1


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    # defs: %name -> rhs text, for the bf16-payload heuristic below
    defs: dict[str, str] = {}
    for line in text.splitlines():
        ls0 = line.strip()
        md = _INSTR_RE.match(ls0)
        if md:
            defs[md.group(2)] = md.group(3)
    for line in text.splitlines():
        mc = _COMP_RE.match(line)
        if mc:
            name = mc.group(2)
            cur = Computation(name=name, is_entry=bool(mc.group(1)))
            comps[name] = cur
            continue
        if cur is None:
            continue
        ls = line.strip()
        m = _INSTR_RE.match(ls)
        if not m:
            continue
        rhs = m.group(3)
        # constants (for while trip counts)
        mconst = re.match(r"s32\[\]\s+constant\((\d+)\)", rhs)
        if mconst:
            cur.max_constant = max(cur.max_constant, int(mconst.group(1)))
        # while instructions
        if re.search(r"\bwhile\(", rhs):
            body = re.search(r"body=%?([\w\.\-]+)", rhs)
            cond = re.search(r"condition=%?([\w\.\-]+)", rhs)
            if body and cond:
                cur.whiles.append((body.group(1), cond.group(1)))
            continue
        # collectives (count -start; skip -done which carries no new traffic)
        for cop in COLLECTIVES:
            if re.search(rf"\b{cop}(-start)?\(", rhs) and f"{cop}-done" not in rhs:
                out_type = rhs.split(cop)[0]
                b = _shape_bytes(out_type)
                if cop == "all-reduce":
                    b *= 2  # ring AR = RS + AG
                # XLA:CPU promotes bf16 collectives to f32 (a wrapped_convert
                # feeds every one — verified empirically). The logical wire
                # payload on TPU is bf16: halve when the operand chain shows
                # a bf16 -> f32 convert.
                if "f32[" in out_type:
                    ops = re.findall(r"\((%[\w\.\-]+)", rhs)
                    for op in ops[:1]:
                        d = defs.get(op, "")
                        if ("convert" in d or "convert" in op) and \
                                ("bf16[" in d or _first_operand_bf16(d, defs)):
                            b //= 2
                            break
                cur.collectives.append((cop, b))
                break
        # calls / fusions / conditionals reference other computations
        for mcall in _CALLED_RE.finditer(rhs):
            for nm in mcall.group(1).replace("%", "").split(","):
                nm = nm.strip()
                if nm:
                    cur.calls.append(nm)
    return comps


def _first_operand_bf16(rhs: str, defs: dict[str, str]) -> bool:
    """One hop deeper: fusion(%x) where %x is bf16."""
    m = re.search(r"\((%[\w\.\-]+)", rhs)
    if not m:
        return False
    d = defs.get(m.group(1), "")
    return d.startswith("bf16[") or " bf16[" in d[:40]


def collective_bytes(text: str) -> dict[str, float]:
    """Per-collective-kind bytes per device per step, loop-adjusted."""
    comps = parse_hlo(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        return {}
    memo: dict[str, dict[str, float]] = {}

    def walk(name: str) -> dict[str, float]:
        if name in memo:
            return memo[name]
        memo[name] = {}  # cycle guard
        c = comps.get(name)
        if c is None:
            return {}
        tot: dict[str, float] = {}
        for kind, b in c.collectives:
            tot[kind] = tot.get(kind, 0.0) + b
        for callee in c.calls:
            if callee in comps and (callee != name):
                for k, v in walk(callee).items():
                    tot[k] = tot.get(k, 0.0) + v
        for body, cond in c.whiles:
            trip = comps[cond].max_constant if cond in comps else 1
            sub = walk(body)
            for k, v in sub.items():
                tot[k] = tot.get(k, 0.0) + trip * v
        memo[name] = tot
        return tot

    out = walk(entry.name)
    out["total"] = sum(out.values())
    return out


def count_collectives(text: str) -> dict[str, int]:
    comps = parse_hlo(text)
    out: dict[str, int] = {}
    for c in comps.values():
        for kind, _ in c.collectives:
            out[kind] = out.get(kind, 0) + 1
    return out
