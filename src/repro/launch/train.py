"""Production training launcher.

Examples::

  # smoke-scale local run (CPU) with checkpoints + auto-resume
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
      --shape train_4k --scale smoke --steps 50 --checkpoint-dir /tmp/ck

  # full-scale (TPU pod): lowers the real cell under the production mesh
  python -m repro.launch.train --arch qwen3-moe-235b-a22b --shape train_4k \
      --mesh single --steps 100000 --checkpoint-dir gs://...

On non-TPU hosts the full-scale path refuses to allocate; use the dry-run
for topology validation and --scale smoke for end-to-end execution.
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import numpy as np

from ..configs import get_arch, get_shapes
from ..configs.reduce import reduce_cell, reduce_config
from ..data import synthetic
from ..distributed.fault_tolerance import StragglerWatchdog, TrainingSupervisor
from ..distributed.partitioning import default_rules
from ..models.common import MeshCtx, NULL_CTX
from ..models.registry import build_cell
from ..models.gnn.sampler import NeighborSampler
from .mesh import make_host_mesh, make_production_mesh


def make_batch_fn(arch_id: str, cfg, family: str, cell, seed: int = 0):
    """Deterministic (step -> host batch) for every family (DESIGN.md §5)."""
    import jax.numpy as jnp

    if family == "lm":
        def fn(step):
            b = synthetic.token_batch(cell.global_batch, cell.seq_len,
                                      cfg.vocab_size, seed=seed + step)
            return {k: jnp.asarray(v) for k, v in b.items()}
        return fn
    if family == "gnn":
        if cell.kind == "minibatch":
            g = synthetic.random_graph(cell.n_nodes, max(cell.n_edges //
                                                         max(cell.n_nodes, 1), 2),
                                       cell.d_feat,
                                       cell.extras.get("n_classes", 41),
                                       seed=seed)
            sampler = NeighborSampler(g, cell.fanout or cfg.sample_sizes,
                                      seed=seed)

            def fn(step):
                b = sampler.sample_batch(step, cell.batch_nodes)
                return {k: jnp.asarray(v) for k, v in b.items()
                        if k != "seeds"}
            return fn
        if cell.kind == "full_graph":
            g = synthetic.random_graph(cell.n_nodes,
                                       max(cell.n_edges // max(cell.n_nodes, 1), 2),
                                       cell.d_feat,
                                       cell.extras.get("n_classes", 7),
                                       seed=seed)
            batch = {
                "features": jnp.asarray(g.features),
                "src": jnp.asarray(g.edge_src), "dst": jnp.asarray(g.edge_dst),
                "labels": jnp.asarray(g.labels),
                "node_mask": jnp.ones(g.n_nodes, jnp.float32),
            }
            return lambda step: batch
        # batched_graphs
        rng = np.random.default_rng(seed)
        gpb, nn, ne, d = (cell.graphs_per_batch, cell.n_nodes, cell.n_edges,
                          cell.d_feat)

        def fn(step):
            r = np.random.default_rng(seed + step)
            return {
                "features": jnp.asarray(
                    r.normal(size=(gpb, nn, d)).astype(np.float32)),
                "edges": jnp.asarray(
                    r.integers(0, nn, (gpb, ne, 2)).astype(np.int32)),
                "edge_mask": jnp.ones((gpb, ne), jnp.float32),
                "labels": jnp.asarray(
                    r.integers(0, cell.extras.get("n_classes", 2), gpb)
                    .astype(np.int32)),
            }
        return fn
    # recsys
    vocabs = {t.name: t.vocab for t in cfg.tables}

    def fn(step):
        b = synthetic.recsys_batch(cell.global_batch, vocabs,
                                   hist_len=cfg.hist_len or cfg.seq_len,
                                   n_fields=cfg.n_fields,
                                   field_vocab=(cfg.tables[0].vocab
                                                if cfg.tables else 1000),
                                   seed=seed + step)
        out = {}
        kind = cfg.kind
        if kind == "bst":
            out = {"hist": b["hist"][:, :cfg.seq_len], "item": b["item"],
                   "user": b["user"], "category": b["category"],
                   "label": b["label"]}
        elif kind == "two_tower":
            out = {"user": b["user"], "hist": b["hist"],
                   "hist_len": b["hist_len"], "item": b["item"],
                   "label": b["label"]}
        elif kind == "autoint":
            out = {"fields": b["fields"], "label": b["label"]}
        else:  # mind
            out = {"hist": b["hist"], "hist_len": b["hist_len"],
                   "item": b["item"], "label": b["label"]}
        return {k: jnp.asarray(v) for k, v in out.items()}
    return fn


def main(argv: Optional[list[str]] = None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--scale", default="smoke", choices=["smoke", "full"])
    ap.add_argument("--mesh", default="host", choices=["host", "single", "multi"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--save-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg, family = get_arch(args.arch)
    shapes = {c.name: c for c in get_shapes(args.arch)}
    train_cells = [c for c in shapes.values() if c.kind in
                   ("train", "full_graph", "minibatch", "batched_graphs")]
    cell = shapes[args.shape] if args.shape else train_cells[0]

    if args.scale == "smoke":
        cfg = reduce_config(cfg, family)
        cell = reduce_cell(cell, family)
        ctx = NULL_CTX
        mesh = None
    else:
        if args.mesh == "host":
            mesh = make_host_mesh()
            ctx = MeshCtx(mesh=mesh, rules={"batch": ("data",),
                                            **default_rules()})
        else:
            mesh = make_production_mesh(multi_pod=(args.mesh == "multi"))
            ctx = MeshCtx(mesh=mesh,
                          rules=default_rules(multi_pod=(args.mesh == "multi")))
        if jax.default_backend() == "cpu" and mesh.size > len(jax.devices()):
            raise SystemExit("full-scale training needs the real pod; "
                             "use --scale smoke or the dry-run")

    prog = build_cell_with(cfg, family, args.arch, cell, ctx)
    params_abs, opt_abs, _ = prog.abstract_args
    key = jax.random.PRNGKey(args.seed)
    params = init_for(cfg, family, cell, key, ctx)
    from ..optim import AdamW
    opt_state = prog.meta["opt"].init(params)

    step_fn = jax.jit(prog.fn, donate_argnums=(0, 1))
    batch_fn = make_batch_fn(args.arch, cfg, family, cell, seed=args.seed)

    sup = TrainingSupervisor(
        step_fn=step_fn, init_state=(params, opt_state), batch_fn=batch_fn,
        checkpoint_dir=args.checkpoint_dir, save_every=args.save_every,
        watchdog=StragglerWatchdog())
    t0 = time.perf_counter()
    report = sup.run(args.steps, log_every=10)
    dt = time.perf_counter() - t0
    for m in report["metrics"][-5:]:
        print("  ", {k: round(v, 4) for k, v in m.items()})
    print(f"trained {args.arch}/{cell.name} ({args.scale}) "
          f"{report['final_step']} steps in {dt:.1f}s; "
          f"stragglers: {len(report['watchdog'].slow_steps)}")
    return 0


def build_cell_with(cfg, family, arch_id, cell, ctx):
    """build_cell, but honoring an already-reduced cfg."""
    from ..models import registry as reg

    if family == "lm":
        prog = reg._lm_cell(arch_id, cfg, cell, ctx)
        prog.meta["opt"] = reg._lm_opt(cfg)
    elif family == "gnn":
        prog = reg._gnn_cell(arch_id, cfg, cell, ctx)
        prog.meta["opt"] = reg._small_opt()
    else:
        prog = reg._recsys_cell(arch_id, cfg, cell, ctx)
        prog.meta["opt"] = reg._small_opt()
    return prog


def init_for(cfg, family, cell, key, ctx):
    if family == "lm":
        from ..models.transformer import model as tm
        return tm.init(cfg, key, ctx)
    if family == "gnn":
        from ..models.gnn import graphsage
        return graphsage.init(cfg, cell.d_feat,
                              cell.extras.get("n_classes", cfg.n_classes), key)
    from ..models import registry as reg
    return reg._RECSYS_MODULES[cfg.kind].init(cfg, key)


if __name__ == "__main__":
    raise SystemExit(main())
