# NOTE: do not import dryrun here — it sets XLA_FLAGS at import time and must
# only be imported as the very first thing in its own process.
from . import mesh
