"""Production mesh builders.

Functions, not module-level constants, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS first).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model); the pod axis is pure
    data parallelism (gradient all-reduce crosses the DCN/ICI pod boundary).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Whatever devices exist locally, as a 1D (data,) mesh — used by tests
    and the CPU-scale examples."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",), axis_types=(AxisType.Auto,))
