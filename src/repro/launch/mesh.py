"""Production mesh builders.

Functions, not module-level constants, so importing this module never
touches jax device state (the dry-run must set XLA_FLAGS first).

``AxisType`` compatibility: ``jax.sharding.AxisType`` (and the matching
``axis_types=`` kwarg of ``jax.make_mesh``) only exist in newer jax. On
older installs we substitute an enum-shaped stand-in and drop the kwarg —
every mesh here is Auto-typed anyway, which is the old default. Import
``AxisType`` / ``make_mesh`` from THIS module, not from ``jax.sharding``.
"""
from __future__ import annotations

import inspect

import jax

try:
    from jax.sharding import AxisType  # jax >= 0.5
except ImportError:
    class AxisType:
        """Stand-in for jax.sharding.AxisType on older jax."""

        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

_MAKE_MESH_TAKES_AXIS_TYPES = (
    "axis_types" in inspect.signature(jax.make_mesh).parameters)


def make_mesh(shape, axes, axis_types=None):
    """``jax.make_mesh`` that tolerates jax without ``axis_types``."""
    if axis_types is not None and _MAKE_MESH_TAKES_AXIS_TYPES:
        return jax.make_mesh(shape, axes, axis_types=axis_types)
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model).
    Multi-pod: 2x16x16 = 512 chips (pod, data, model); the pod axis is pure
    data parallelism (gradient all-reduce crosses the DCN/ICI pod boundary).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Whatever devices exist locally, as a 1D (data,) mesh — used by tests
    and the CPU-scale examples."""
    n = len(jax.devices())
    return make_mesh((n,), ("data",), axis_types=(AxisType.Auto,))
