"""Sharded, prefetching, elastically-resumable data pipeline.

Design (DESIGN.md §5): every batch is a pure function of (seed, step), so
 * resuming at step k replays the exact stream (fault tolerance),
 * any host can synthesize any shard (elastic re-scaling never loses data),
 * no coordination is needed between hosts.

``Prefetcher`` overlaps host-side batch synthesis with device compute via a
background thread + bounded queue (the CPU-container stand-in for the
multi-host input pipeline; on real fleets the per-host loader feeds its
process-local shard of the global batch).
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Iterator, Optional

import jax
import numpy as np


class StepIndexedSource:
    """Deterministic (seed, step) -> global batch function."""

    def __init__(self, make_batch: Callable[[int], Any], seed: int = 0):
        self._make = make_batch
        self.seed = seed

    def batch_at(self, step: int) -> Any:
        return self._make(step)

    def iterate(self, start_step: int = 0) -> Iterator[Any]:
        step = start_step
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch with a bounded queue (depth 2 by default)."""

    def __init__(self, it: Iterator[Any], depth: int = 2,
                 device_put: Optional[Callable[[Any], Any]] = None):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._err: Optional[BaseException] = None
        self._stop = threading.Event()
        self._put = device_put or (lambda x: x)

        def run():
            try:
                for item in it:
                    if self._stop.is_set():
                        return
                    self._q.put(self._put(item))
            except BaseException as e:  # surfaced on next __next__
                self._err = e
            finally:
                self._q.put(_SENTINEL)

        self._t = threading.Thread(target=run, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is _SENTINEL:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        while not self._q.empty():
            self._q.get_nowait()


_SENTINEL = object()


def shard_batch(batch: Any, sharding) -> Any:
    """Place a host-global batch onto the mesh with the given sharding tree."""
    return jax.tree.map(
        lambda x, s: jax.device_put(np.asarray(x), s), batch, sharding)
