from . import synthetic
