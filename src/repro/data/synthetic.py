"""Synthetic corpora for every substrate (offline container; DESIGN.md §6).

``embedding_corpus`` is the paper-dataset analogue: anisotropic low-rank
Gaussian mixture with a power-law singular spectrum and per-cluster rotations.
This is the regime where PCA and RAE genuinely differ — information density
varies by direction, so non-orthogonal bases can beat variance-optimal ones
(the paper's §3.2 argument).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional

import numpy as np


# The paper's four datasets, by embedding dimension.
PAPER_DATASETS = {
    "imagenet_like": dict(dim=384, n_clusters=24, intrinsic=96),
    "celeba_like": dict(dim=512, n_clusters=16, intrinsic=128),
    "imdb_like": dict(dim=768, n_clusters=8, intrinsic=160),
    "flickr_like": dict(dim=1024, n_clusters=12, intrinsic=224),
}


def embedding_corpus(
    n: int,
    dim: int,
    n_clusters: int = 8,
    intrinsic: Optional[int] = None,
    spectrum_decay: float = 0.7,
    noise: float = 0.02,
    normalize: bool = False,
    seed: int = 0,
) -> np.ndarray:
    """[n, dim] float32 embeddings: mixture of rotated low-rank Gaussians."""
    rng = np.random.default_rng(seed)
    r = intrinsic or max(dim // 4, 8)
    # Real transformer/CLIP embeddings share one dominant anisotropic
    # spectrum across the whole corpus (the regime where variance-aware DR
    # beats data-oblivious JL projections); clusters are centers within the
    # dominant subspace plus small per-cluster basis perturbations.
    spec = (np.arange(1, r + 1, dtype=np.float32) ** (-spectrum_decay))
    shared, _ = np.linalg.qr(rng.normal(size=(dim, r)).astype(np.float32))
    out = np.empty((n, dim), np.float32)
    sizes = rng.multinomial(n, np.ones(n_clusters) / n_clusters)
    start = 0
    for c, sz in enumerate(sizes):
        if sz == 0:
            continue
        # mild per-cluster rotation of the shared basis
        pert = rng.normal(scale=0.15, size=(dim, r)).astype(np.float32)
        basis, _ = np.linalg.qr(shared + pert)
        # centers live in the dominant half of the shared subspace
        cz = np.zeros(r, np.float32)
        cz[: max(r // 2, 1)] = rng.normal(
            scale=1.5, size=max(r // 2, 1)) * spec[: max(r // 2, 1)]
        center = shared @ cz
        z = rng.normal(size=(sz, r)).astype(np.float32) * spec[None, :]
        x = z @ basis.T + center[None, :]
        x += rng.normal(scale=noise, size=x.shape).astype(np.float32)
        out[start:start + sz] = x
        start += sz
    rng.shuffle(out)
    if normalize:
        out /= np.maximum(np.linalg.norm(out, axis=1, keepdims=True), 1e-12)
    return out


def paper_dataset(name: str, n: int, seed: int = 0, **overrides) -> np.ndarray:
    kw = dict(PAPER_DATASETS[name])
    kw.update(overrides)
    return embedding_corpus(n=n, seed=seed, **kw)


def train_test_split(x: np.ndarray, test_frac: float = 0.1, seed: int = 0
                     ) -> tuple[np.ndarray, np.ndarray]:
    """The paper's 9:1 split."""
    rng = np.random.default_rng(seed)
    idx = rng.permutation(x.shape[0])
    n_test = int(round(x.shape[0] * test_frac))
    return x[idx[n_test:]], x[idx[:n_test]]


# ---------------------------------------------------------------------------
# LM token streams
# ---------------------------------------------------------------------------
def token_batch(batch: int, seq: int, vocab: int, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    # zipfian token distribution (realistic softmax pressure)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = 1.0 / ranks
    p /= p.sum()
    toks = rng.choice(vocab, size=(batch, seq + 1), p=p).astype(np.int32)
    return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


# ---------------------------------------------------------------------------
# Graphs
# ---------------------------------------------------------------------------
@dataclass
class Graph:
    """CSR graph + features. The CSR arrays power the neighbor sampler."""

    n_nodes: int
    features: np.ndarray       # [N, d]
    labels: np.ndarray         # [N]
    edge_src: np.ndarray       # [E] (COO, sorted by src)
    edge_dst: np.ndarray       # [E]
    indptr: np.ndarray         # [N+1] CSR offsets into edge_dst

    @property
    def n_edges(self) -> int:
        return len(self.edge_src)


def random_graph(n_nodes: int, avg_degree: int, d_feat: int, n_classes: int,
                 seed: int = 0) -> Graph:
    """Power-law-ish random graph with community-correlated features."""
    rng = np.random.default_rng(seed)
    n_edges = n_nodes * avg_degree
    # preferential-attachment-flavored degree distribution
    w = rng.pareto(2.0, n_nodes) + 1.0
    p = w / w.sum()
    src = rng.choice(n_nodes, n_edges, p=p).astype(np.int32)
    dst = rng.integers(0, n_nodes, n_edges).astype(np.int32)
    # communities drive labels + features
    comm = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    centers = rng.normal(size=(n_classes, d_feat)).astype(np.float32)
    feats = centers[comm] + rng.normal(scale=0.5, size=(n_nodes, d_feat)).astype(np.float32)
    order = np.argsort(src, kind="stable")
    src, dst = src[order], dst[order]
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.add.at(indptr, src + 1, 1)
    indptr = np.cumsum(indptr)
    return Graph(n_nodes=n_nodes, features=feats, labels=comm,
                 edge_src=src, edge_dst=dst, indptr=indptr)


# ---------------------------------------------------------------------------
# RecSys click logs
# ---------------------------------------------------------------------------
def recsys_batch(batch: int, table_vocabs: dict[str, int], hist_len: int = 0,
                 n_fields: int = 0, field_vocab: int = 200_000,
                 seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    out: dict = {}
    for name, vocab in table_vocabs.items():
        out[name] = rng.integers(0, vocab, batch).astype(np.int32)
    if hist_len:
        vocab = table_vocabs.get("item", table_vocabs.get("hist_item", 1000))
        out["hist"] = rng.integers(0, vocab, (batch, hist_len)).astype(np.int32)
        out["hist_len"] = rng.integers(1, hist_len + 1, batch).astype(np.int32)
    if n_fields:
        out["fields"] = rng.integers(0, field_vocab,
                                     (batch, n_fields)).astype(np.int32)
    out["label"] = (rng.random(batch) < 0.2).astype(np.float32)
    return out
