"""RAE attached to an LM's embedding head (arch-applicability, DESIGN.md §9).

    PYTHONPATH=src python examples/lm_embedding_compression.py

Runs a reduced llama3.2-1b, harvests pooled hidden-state embeddings from
``prefill`` over a synthetic document set, trains RAE on them, and measures
k-NN preservation of the compressed document embeddings — the
retrieval-augmented-serving integration path.
"""
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import RAEConfig, get_arch
from repro.configs.reduce import reduce_config
from repro.core import metrics, trainer
from repro.core import rae as rae_lib
from repro.data import synthetic
from repro.models.common import NULL_CTX
from repro.models.transformer import model as tm


def main():
    cfg, family = get_arch("llama3.2-1b")
    cfg = reduce_config(cfg, family)
    params = tm.init(cfg, jax.random.PRNGKey(0))

    print("=== harvesting LM document embeddings (prefill head) ===")
    n_docs, seq = 768, 48
    prefill = jax.jit(lambda p, t: tm.prefill(p, t, cfg, NULL_CTX)[1])
    embeds = []
    for i in range(0, n_docs, 64):
        batch = synthetic.token_batch(64, seq, cfg.vocab_size, seed=i)
        embeds.append(np.asarray(prefill(params, jnp.asarray(batch["tokens"]))))
    x = np.concatenate(embeds)  # [n_docs, d_model]
    print(f"  {x.shape[0]} docs x {x.shape[1]}-d embeddings")

    tr, te = synthetic.train_test_split(x)
    rae_cfg = RAEConfig(in_dim=x.shape[1], out_dim=x.shape[1] // 4,
                        steps=600, weight_decay=1e-2)
    print(f"=== RAE {rae_cfg.in_dim} -> {rae_cfg.out_dim} on LM embeddings ===")
    res = trainer.train(rae_cfg, tr, log_every=200)
    z = np.asarray(rae_lib.encode(res.params, jnp.asarray(te)))
    for metric in ("euclidean", "cosine"):
        acc = metrics.preservation_accuracy(te, z, k=5, metric=metric)
        print(f"  P_overall@5 ({metric}): {100*acc:.2f}%")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
