"""End-to-end serving driver: a vector-search service with batched requests.

    PYTHONPATH=src python examples/rae_retrieval.py

The paper's deployment story: ingest a corpus, train RAE, encode the corpus
into R^m, then serve batched k-NN queries with TWO-STAGE search (scan the
reduced corpus with the fused distance+top-k engine, rerank the shortlist in
the original space). Reports recall@k vs the exact scan and latency.
"""
import sys

sys.path.insert(0, "src")

from repro.launch import serve  # noqa: E402


def main():
    return serve.main([
        "--n", "30000", "--dim", "512", "--m", "96", "--k", "10",
        "--queries", "128", "--batches", "6", "--steps", "800",
    ])


if __name__ == "__main__":
    raise SystemExit(main())
