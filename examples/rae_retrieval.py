"""End-to-end serving driver on the unified retrieval API.

    PYTHONPATH=src python examples/rae_retrieval.py

The paper's deployment story through ``repro.api``: synthesize a corpus,
``index_factory("RAE96,IVF128,Rerank4")`` builds the full stack (train RAE,
encode the corpus into R^m, coarse-quantize the reduced space), then serve
batched k-NN queries with full-space rerank. Reports recall@k vs the exact
scan and latency. Swap the spec for "RAE96,Flat,Rerank4" (exact reduced
scan) or "PCA96,Flat,Rerank4" (baseline reducer) — same serving path.
"""
import sys

sys.path.insert(0, "src")

from repro.launch import serve  # noqa: E402


def main():
    return serve.main([
        "--n", "30000", "--dim", "512", "--k", "10",
        "--index-spec", "RAE96,IVF128,Rerank4",
        "--queries", "128", "--batches", "6", "--steps", "800",
    ])


if __name__ == "__main__":
    raise SystemExit(main())
