"""Fault-tolerant distributed training demo: crash mid-run, auto-resume,
verify the resumed run matches the uninterrupted one bit-for-bit.

    PYTHONPATH=src python examples/fault_tolerant_training.py

Trains a (reduced) llama3.2-1b for 60 steps under the TrainingSupervisor:
async sharded checkpoints every 20 steps, an injected crash at step 45, and
a second supervisor that resumes from step 40 and replays the identical
step-indexed data stream.
"""
import sys

sys.path.insert(0, "src")

import shutil
import tempfile

import jax
import numpy as np

from repro.configs import get_arch, get_shapes
from repro.configs.reduce import reduce_cell, reduce_config
from repro.distributed.fault_tolerance import (SimulatedFailure,
                                               TrainingSupervisor)
from repro.launch.train import build_cell_with, init_for, make_batch_fn
from repro.models.common import NULL_CTX


def main():
    arch = "llama3.2-1b"
    cfg, family = get_arch(arch)
    cfg = reduce_config(cfg, family)
    cell = reduce_cell([c for c in get_shapes(arch)
                        if c.kind == "train"][0], family)
    prog = build_cell_with(cfg, family, arch, cell, NULL_CTX)
    params = init_for(cfg, family, cell, jax.random.PRNGKey(0), NULL_CTX)
    opt_state = prog.meta["opt"].init(params)
    step_fn = jax.jit(prog.fn)
    batch_fn = make_batch_fn(arch, cfg, family, cell)

    ckdir = tempfile.mkdtemp(prefix="raex_ft_")
    print(f"checkpoints -> {ckdir}")

    print("=== run A: uninterrupted 60 steps ===")
    sup_a = TrainingSupervisor(step_fn, (params, opt_state), batch_fn)
    rep_a = sup_a.run(60, log_every=20)
    loss_a = rep_a["metrics"][-1]["loss"]
    print(f"  final loss {loss_a:.5f}")

    print("=== run B: crash injected at step 45 ===")
    sup_b = TrainingSupervisor(step_fn, (params, opt_state), batch_fn,
                               checkpoint_dir=ckdir, save_every=20)
    try:
        sup_b.run(60, fail_at_step=45, log_every=20)
    except SimulatedFailure as e:
        print(f"  CRASH: {e}")
    sup_b.ckpt.wait()

    print("=== run C: auto-resume ===")
    sup_c = TrainingSupervisor(step_fn, (params, opt_state), batch_fn,
                               checkpoint_dir=ckdir, save_every=20)
    print(f"  resumed from step {sup_c.start_step}")
    rep_c = sup_c.run(60, log_every=20)
    loss_c = rep_c["metrics"][-1]["loss"]
    print(f"  final loss {loss_c:.5f}")

    w_a = np.asarray(jax.tree.leaves(sup_a.state[0])[0])
    w_c = np.asarray(jax.tree.leaves(sup_c.state[0])[0])
    same = np.allclose(w_a, w_c, rtol=1e-6)
    print(f"resumed == uninterrupted: {same}")
    shutil.rmtree(ckdir, ignore_errors=True)
    assert same
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
