"""Quickstart: train RAE on an embedding corpus and measure k-NN preservation.

    PYTHONPATH=src python examples/quickstart.py

Reproduces the paper's core loop at laptop scale: corpus -> RAE (AdamW
weight decay = lambda, cosine annealing) -> P_overall vs PCA, plus the
theory checks (condition number, norm-distortion bounds).
"""
import sys

sys.path.insert(0, "src")

import jax.numpy as jnp
import numpy as np

from repro.configs import RAEConfig
from repro.core import metrics, rae, spectral, theory, trainer
from repro.core.baselines import PCA
from repro.data import synthetic


def main():
    print("=== corpus: imdb-like 768-d embeddings ===")
    data = synthetic.paper_dataset("imdb_like", n=4000, seed=0)
    train_x, test_x = synthetic.train_test_split(data)  # paper's 9:1 split

    # lambda tuned via the Figure-1 sweep (benchmarks/fig1_weight_decay.py):
    # kappa(W) is minimal near 0.3-1.0 on this corpus
    cfg = RAEConfig(in_dim=768, out_dim=256, steps=1500, weight_decay=0.3)
    print(f"=== training RAE {cfg.in_dim} -> {cfg.out_dim} "
          f"(lambda={cfg.weight_decay}) ===")
    result = trainer.train(cfg, train_x, log_every=300)
    for h in result.history:
        print(f"  step {h['step']:4d}  loss {h['loss']:9.3f}  "
              f"lr {h['lr']:.2e}")
    print(f"  wall time: {result.wall_time_s:.1f}s")

    z = np.asarray(rae.encode(result.params, jnp.asarray(test_x)))

    print("=== k-NN preservation (P_overall, Eq. 4) ===")
    pca = PCA(cfg.out_dim).fit(train_x)
    z_pca = pca.transform(test_x)
    for metric in ("euclidean", "cosine"):
        a_rae = metrics.preservation_accuracy(test_x, z, k=5, metric=metric)
        a_pca = metrics.preservation_accuracy(test_x, z_pca, k=5,
                                              metric=metric)
        print(f"  {metric:9s}: RAE {100*a_rae:5.2f}%   PCA {100*a_pca:5.2f}%")

    print("=== theory (Section 3.3) ===")
    w = rae.encoder_matrix(result.params)
    st = spectral.analyze(w)
    print(f"  sigma_max={float(st.sigma_max):.3f} "
          f"sigma_min={float(st.sigma_min):.3f} "
          f"kappa(W)={float(st.condition_number):.3f} "
          f"(||W||_F={float(st.frobenius):.3f} >= sigma_max: Eq. 8)")
    ok = theory.norm_bounds_hold(w, jnp.asarray(test_x))
    print(f"  Eq. 15 bounds hold on the test set (row-space): {bool(ok)}")
    cert = theory.certified_fraction(w, jnp.asarray(test_x[:256]), k=5)
    print(f"  kNN relations provably preserved by Eq. 16: {100*float(cert):.1f}%")


if __name__ == "__main__":
    main()
